"""Continuous micro-batching ANN server over a :class:`~repro.core.suco.SuCoEngine`.

The LLM serving driver (:mod:`repro.launch.serve`) admits new sequences into
fixed decode slots at step boundaries; this is the same design with the ANN
engine as the backend.  Heterogeneous ``(query, k)`` requests enter an
admission queue; at every step boundary the scheduler forms one micro-batch
of same-``k`` requests (k is a compile-time shape, so mixed-k traffic
resolves into alternating steps, FIFO within each k), the engine pads the
batch to a policy bucket (:func:`repro.core.suco.batch_bucket`) and runs the
pre-compiled ``(bucket, k)`` executable.  Per-request latency is accounted
from admission to result materialisation, and every step records the
engine's compile count — flat-after-warmup is the serving invariant the
benchmark suite asserts.

Two step disciplines over the same admission queue:

* :class:`AnnServer` — synchronous: each step dispatches one micro-batch
  and blocks on its results before the next admission.  Simplest
  accounting, lowest single-request latency when the queue never holds
  more than one batch.
* :class:`AsyncAnnServer` — pipelined: dispatch is decoupled from result
  delivery through a bounded in-flight window (``depth``).  jax dispatch
  is asynchronous, so enqueueing batch t+1 returns while batch t still
  executes; the host forms and pads the next micro-batch during device
  time and only blocks (``np.asarray`` materialisation) when the window
  is full or the queue drains.  Per-request latency splits into queueing
  (admission -> dispatch) and execution (dispatch -> materialisation).

CPU-scale usage:
  PYTHONPATH=src python -m repro.serve.ann --n 20000 --d 32 --requests 64
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from collections import deque
from typing import Callable, Sequence

import numpy as np

from repro.core.sc_linear import QueryResult
from repro.core.suco import EnginePolicy, SuCoConfig, SuCoEngine, batch_bucket

__all__ = [
    "AnnRequest",
    "StepRecord",
    "AnnServer",
    "AsyncAnnServer",
    "latency_summary",
]


@dataclasses.dataclass
class AnnRequest:
    """One k-ANN request: a single query vector and its own ``k``."""

    rid: int
    query: np.ndarray  # (d,)
    k: int
    t_submit: float = 0.0  # admission-queue entry
    t_start: float = 0.0  # micro-batch dispatch
    t_done: float = 0.0  # results materialised on host
    ids: np.ndarray | None = None  # (k,) int32
    dists: np.ndarray | None = None  # (k,)
    error: str | None = None  # rejection reason (bad shape / k out of range)

    @property
    def done(self) -> bool:
        return self.ids is not None

    @property
    def latency_s(self) -> float:
        """Admission-to-result latency (queueing + padding + execution)."""
        return self.t_done - self.t_submit

    @property
    def queue_s(self) -> float:
        """Queueing latency: admission to micro-batch dispatch."""
        return self.t_start - self.t_submit

    @property
    def exec_s(self) -> float:
        """Execution latency: dispatch to host-side materialisation (for
        the pipelined server this includes time spent waiting behind other
        in-flight batches on the device stream)."""
        return self.t_done - self.t_start


@dataclasses.dataclass(frozen=True)
class StepRecord:
    """Per-step accounting: what ran and whether the engine recompiled."""

    n_requests: int
    k: int
    bucket: int
    step_s: float  # dispatch -> results materialised on host
    compile_count: int  # engine executables after this step
    dispatch_s: float = 0.0  # host time to form/pad/enqueue the batch
    # (the synchronous server folds dispatch into step_s and leaves this 0)


class AnnServer:
    """Continuous micro-batching over a warmed :class:`SuCoEngine`.

    Mirrors :class:`repro.launch.serve.Server`'s slot design: ``max_batch``
    is the slot count, the queue refills the batch at each step boundary.
    Requests with different ``k`` cannot share an executable, so a step
    serves the FIFO-first ``k`` and defers the rest — arrival order is
    preserved within every ``k`` class and across deferrals.
    """

    def __init__(
        self,
        engine: SuCoEngine,
        max_batch: int = 64,
        clock: Callable[[], float] = time.perf_counter,
    ):
        self.engine = engine
        self.max_batch = max_batch
        self.clock = clock
        self.queue: deque[AnnRequest] = deque()
        self.completed: list[AnnRequest] = []
        self.steps: list[StepRecord] = []

    def submit(self, req: AnnRequest) -> None:
        req.t_submit = self.clock()
        self.queue.append(req)

    def submit_many(self, reqs: Sequence[AnnRequest]) -> None:
        for r in reqs:
            self.submit(r)

    def _form_batch(self) -> tuple[list[AnnRequest], int]:
        """Pop the next same-``k`` micro-batch off the admission queue.

        Serves the FIFO-first ``k`` and defers other-``k`` requests without
        losing their queue rank.
        """
        k = self.queue[0].k
        batch: list[AnnRequest] = []
        deferred: deque[AnnRequest] = deque()
        while self.queue and len(batch) < self.max_batch:
            r = self.queue.popleft()
            (batch if r.k == k else deferred).append(r)
        self.queue = deferred + self.queue  # deferrals keep their queue rank
        return batch, k

    def step(self) -> list[AnnRequest]:
        """Run one micro-batch; returns the requests it completed."""
        if not self.queue:
            return []
        batch, k = self._form_batch()

        t0 = self.clock()
        for r in batch:
            r.t_start = t0
        try:
            res = self.engine.query(np.stack([r.query for r in batch]), k=k)
            ids = np.asarray(res.ids)  # jaxlint: sync-ok — sync serving step
            dists = np.asarray(res.dists)  # jaxlint: sync-ok
            t1 = self.clock()
            for i, r in enumerate(batch):
                r.ids, r.dists, r.t_done = ids[i], dists[i], t1
        except ValueError as e:
            # A malformed request (wrong dim, k out of range) must not sink
            # the healthy requests batched with it: the whole micro-batch is
            # completed-with-error and the server keeps draining.
            t1 = self.clock()
            for r in batch:
                r.error, r.t_done = str(e), t1
        self.completed.extend(batch)
        self.steps.append(
            StepRecord(
                n_requests=len(batch),
                k=k,
                bucket=batch_bucket(len(batch), self.engine.policy.batch_buckets),
                step_s=t1 - t0,
                compile_count=self.engine.compile_count,
            )
        )
        return batch

    def run_until_drained(self) -> list[AnnRequest]:
        while self.queue:
            self.step()
        return self.completed


@dataclasses.dataclass
class _Inflight:
    """A dispatched-but-unmaterialised micro-batch riding the device stream."""

    batch: list[AnnRequest]
    k: int
    result: QueryResult
    t_dispatch: float
    dispatch_s: float


class AsyncAnnServer(AnnServer):
    """Pipelined continuous micro-batching: dispatch overlaps execution.

    The double-buffered step loop the synchronous server cannot express:
    ``step`` forms, pads and *enqueues* the next micro-batch — jax
    dispatch is asynchronous, so the call returns while the previous
    batch still executes — and results are materialised
    (``np.asarray``, the only blocking point) only once ``depth``
    micro-batches are in flight or the queue drains.  With the default
    ``depth=2`` the host assembles batch t+1 while batch t executes;
    the device stream never waits on host-side batch formation.

    Completion order equals dispatch order (the in-flight window is a
    FIFO), so results are a permutation of the synchronous server's only
    across the interleaving of ``k`` classes — per request the answer is
    identical.  A malformed micro-batch fails at dispatch (the engine
    validates shapes/k before enqueueing) and completes-with-error
    without touching the healthy batches already in flight.
    """

    def __init__(
        self,
        engine: SuCoEngine,
        max_batch: int = 64,
        clock: Callable[[], float] = time.perf_counter,
        *,
        depth: int = 2,
    ):
        super().__init__(engine, max_batch, clock)
        if depth < 1:
            raise ValueError(f"pipeline depth must be >= 1, got {depth}")
        self.depth = depth
        self._inflight: deque[_Inflight] = deque()

    @property
    def inflight(self) -> int:
        """Micro-batches dispatched but not yet materialised."""
        return len(self._inflight)

    def _dispatch(self) -> None:
        """Form the next micro-batch and enqueue it on the device (non-blocking)."""
        batch, k = self._form_batch()
        t0 = self.clock()
        for r in batch:
            r.t_start = t0
        try:
            res = self.engine.query(np.stack([r.query for r in batch]), k=k)
        except ValueError as e:
            # Validation failures surface here, before anything reaches the
            # device: the malformed micro-batch completes-with-error and the
            # in-flight healthy batches are untouched.
            t1 = self.clock()
            for r in batch:
                r.error, r.t_done = str(e), t1
            self.completed.extend(batch)
            self.steps.append(
                StepRecord(
                    n_requests=len(batch),
                    k=k,
                    bucket=batch_bucket(len(batch), self.engine.policy.batch_buckets),
                    step_s=t1 - t0,
                    compile_count=self.engine.compile_count,
                    dispatch_s=t1 - t0,
                )
            )
            return
        self._inflight.append(
            _Inflight(batch, k, res, t0, dispatch_s=self.clock() - t0)
        )

    def _retire(self) -> list[AnnRequest]:
        """Materialise the oldest in-flight batch (blocks until it is done)."""
        fl = self._inflight.popleft()
        # The ONE intentional blocking point of the async hot path: retiring
        # the oldest in-flight batch materialises its results.
        ids = np.asarray(fl.result.ids)  # jaxlint: sync-ok — the retire point
        dists = np.asarray(fl.result.dists)  # jaxlint: sync-ok
        t1 = self.clock()
        for i, r in enumerate(fl.batch):
            r.ids, r.dists, r.t_done = ids[i], dists[i], t1
        self.completed.extend(fl.batch)
        self.steps.append(
            StepRecord(
                n_requests=len(fl.batch),
                k=fl.k,
                bucket=batch_bucket(len(fl.batch), self.engine.policy.batch_buckets),
                step_s=t1 - fl.t_dispatch,
                compile_count=self.engine.compile_count,
                dispatch_s=fl.dispatch_s,
            )
        )
        return fl.batch

    def step(self) -> list[AnnRequest]:
        """Dispatch the next micro-batch; retire batches past the window.

        Returns the requests *completed* this step (possibly none — the
        freshly dispatched batch completes on a later step).
        """
        before = len(self.completed)
        if self.queue:
            self._dispatch()
        while len(self._inflight) > self.depth:
            self._retire()
        return self.completed[before:]

    def flush(self) -> list[AnnRequest]:
        """Materialise every in-flight batch (result delivery barrier)."""
        done: list[AnnRequest] = []
        while self._inflight:
            done.extend(self._retire())
        return done

    def run_until_drained(self) -> list[AnnRequest]:
        while self.queue:
            self.step()
        self.flush()
        return self.completed


def latency_summary(requests: Sequence[AnnRequest]) -> dict:
    """QPS + latency percentiles for a completed request set.

    End-to-end latency is split into its queueing (admission -> dispatch)
    and execution (dispatch -> materialisation) components so pipelined
    and synchronous runs can be compared on where requests spend time,
    not just on the total.
    """
    done = [r for r in requests if r.done]
    if not done:
        # Zeroed summary with the full key set: consumers (the CLI report,
        # dashboards) index these keys unconditionally, and np.percentile on
        # an empty array raises.
        return dict(
            n_requests=0,
            qps=0.0,
            p50_ms=0.0,
            p99_ms=0.0,
            mean_ms=0.0,
            max_ms=0.0,
            queue_p50_ms=0.0,
            queue_p99_ms=0.0,
            exec_p50_ms=0.0,
            exec_p99_ms=0.0,
        )
    lat = np.asarray([r.latency_s for r in done])
    queue = np.asarray([r.queue_s for r in done])
    execu = np.asarray([r.exec_s for r in done])
    wall = max(r.t_done for r in done) - min(r.t_submit for r in done)
    return dict(
        n_requests=len(done),
        qps=len(done) / wall if wall > 0 else float("inf"),
        p50_ms=float(np.percentile(lat, 50) * 1e3),
        p99_ms=float(np.percentile(lat, 99) * 1e3),
        mean_ms=float(lat.mean() * 1e3),
        max_ms=float(lat.max() * 1e3),
        queue_p50_ms=float(np.percentile(queue, 50) * 1e3),
        queue_p99_ms=float(np.percentile(queue, 99) * 1e3),
        exec_p50_ms=float(np.percentile(execu, 50) * 1e3),
        exec_p99_ms=float(np.percentile(execu, 99) * 1e3),
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--d", type=int, default=32)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sync", action="store_true",
                    help="use the synchronous step loop (default: pipelined)")
    ap.add_argument("--depth", type=int, default=2,
                    help="pipelined in-flight window (ignored with --sync)")
    args = ap.parse_args()

    from repro.data import make_dataset

    ds = make_dataset("gaussian_mixture", args.n, args.d, m=1, k=10, seed=args.seed)
    engine = SuCoEngine.build(
        ds.x,
        SuCoConfig(n_subspaces=8, sqrt_k=16, kmeans_iters=4, seed=args.seed),
        policy=EnginePolicy(alpha=0.05, beta=0.02),
    )
    rng = np.random.default_rng(args.seed)
    # cover every bucket a <= max_batch micro-batch can land in
    engine.warmup(batch_sizes=range(1, args.max_batch + 1), ks=(5, 10))
    if args.sync:
        server = AnnServer(engine, max_batch=args.max_batch)
    else:
        server = AsyncAnnServer(engine, max_batch=args.max_batch, depth=args.depth)
    server.submit_many(
        AnnRequest(i, ds.x[rng.integers(0, args.n)], k=int(rng.choice([5, 10])))
        for i in range(args.requests)
    )
    done = server.run_until_drained()
    s = latency_summary(done)
    print(
        f"[ann-serve{'' if args.sync else '-async'}] "
        f"{s['n_requests']} requests in {len(server.steps)} steps: "
        f"{s['qps']:.1f} qps, p50 {s['p50_ms']:.1f} ms, p99 {s['p99_ms']:.1f} ms "
        f"(queue p50 {s['queue_p50_ms']:.1f} / exec p50 {s['exec_p50_ms']:.1f}), "
        f"executables {engine.compile_count}"
    )


if __name__ == "__main__":
    main()
