"""Continuous micro-batching ANN server over a :class:`~repro.core.suco.SuCoEngine`.

The LLM serving driver (:mod:`repro.launch.serve`) admits new sequences into
fixed decode slots at step boundaries; this is the same design with the ANN
engine as the backend.  Heterogeneous ``(query, k)`` requests enter an
admission queue; at every step boundary the scheduler forms one micro-batch
of same-``k`` requests (k is a compile-time shape, so mixed-k traffic
resolves into alternating steps), the engine pads the batch to a policy
bucket (:func:`repro.core.suco.batch_bucket`) and runs the pre-compiled
``(bucket, k)`` executable.  Per-request latency is accounted from admission
to result materialisation, and every step records the engine's compile count
— flat-after-warmup is the serving invariant the benchmark suite asserts.

Two step disciplines over the same admission queue:

* :class:`AnnServer` — synchronous: each step dispatches one micro-batch
  and blocks on its results before the next admission.  Simplest
  accounting, lowest single-request latency when the queue never holds
  more than one batch.
* :class:`AsyncAnnServer` — pipelined: dispatch is decoupled from result
  delivery through a bounded in-flight window (``depth``).  jax dispatch
  is asynchronous, so enqueueing batch t+1 returns while batch t still
  executes; the host forms and pads the next micro-batch during device
  time and only blocks (``np.asarray`` materialisation) when the window
  is full or the queue drains.  Per-request latency splits into queueing
  (admission -> dispatch) and execution (dispatch -> materialisation).

Resilience layer (both servers, ``docs/serving_resilience.md``):

* **Deadlines** — ``AnnRequest.deadline_s`` is a relative latency budget
  fixed into an absolute ``t_deadline`` at admission.  Batches form
  oldest-deadline-first (FIFO among deadline ties and deadline-free
  requests), and requests that cannot finish in time — their deadline
  precedes ``now`` plus the recent execution-latency estimate from the
  queue/exec split — are expired at dispatch time instead of burning a
  batch slot.
* **Admission control** — ``max_queue`` bounds the admission queue;
  requests beyond it are shed at ``submit`` with an explicit error
  instead of queueing into a deadline they can no longer meet.
* **Degraded mode** — an :class:`OverloadController` watches queue depth
  and head-of-queue wait and steps the server along a
  :class:`DegradationLadder` of pre-warmed engines with reduced
  (alpha, beta, survivor_cap) budgets
  (:meth:`~repro.core.suco.EnginePolicy.degraded`).  Every answer served
  through a ladder carries the Theorem-2 floor recomputed for its level's
  budget (:func:`repro.core.theory.degraded_budget_bound`) on
  ``AnnRequest.quality_bound`` — degraded answers are *quantified*, never
  silent.  Ladder engines are warmed up front, so degrading never
  retraces.
* **Fault isolation** — a dispatch failure is retried once after a
  jittered backoff; if the batch still fails, each request is served
  individually so one poison query fails only its own request.

CPU-scale usage:
  PYTHONPATH=src python -m repro.serve.ann --n 20000 --d 32 --requests 64
"""

from __future__ import annotations

import argparse
import dataclasses
import math
import time
from collections import deque
from typing import Callable, Sequence

import numpy as np

from repro.core import theory
from repro.core.sc_linear import QueryResult
from repro.core.suco import EnginePolicy, SuCoConfig, SuCoEngine, batch_bucket

__all__ = [
    "AnnRequest",
    "StepRecord",
    "OverloadController",
    "DegradationLadder",
    "AnnServer",
    "AsyncAnnServer",
    "latency_summary",
]


@dataclasses.dataclass
class AnnRequest:
    """One k-ANN request: a single query vector and its own ``k``."""

    rid: int
    query: np.ndarray  # (d,)
    k: int
    deadline_s: float | None = None  # relative latency budget (None = none)
    t_submit: float = 0.0  # admission-queue entry
    t_start: float = 0.0  # micro-batch dispatch
    t_done: float = 0.0  # results materialised on host (or rejection time)
    t_deadline: float = math.inf  # absolute deadline, fixed at admission
    ids: np.ndarray | None = None  # (k,) int32
    dists: np.ndarray | None = None  # (k,)
    error: str | None = None  # rejection reason (bad input / shed / expired)
    shed: bool = False  # rejected at admission (queue full)
    expired: bool = False  # deadline passed before dispatch
    degrade_level: int = 0  # ladder level the answer was served at
    quality_bound: float | None = None  # Theorem-2 floor for that level
    retries: int = 0  # transient-dispatch-error retries spent

    @property
    def done(self) -> bool:
        return self.ids is not None

    @property
    def finished(self) -> bool:
        """Answered or terminally rejected (error / shed / expired)."""
        return self.ids is not None or self.error is not None

    @property
    def hit_deadline(self) -> bool:
        """Answered within the deadline (vacuously true without one)."""
        return self.done and self.t_done <= self.t_deadline

    @property
    def latency_s(self) -> float:
        """Admission-to-result latency (queueing + padding + execution)."""
        return self.t_done - self.t_submit

    @property
    def queue_s(self) -> float:
        """Queueing latency: admission to micro-batch dispatch."""
        return self.t_start - self.t_submit

    @property
    def exec_s(self) -> float:
        """Execution latency: dispatch to host-side materialisation (for
        the pipelined server this includes time spent waiting behind other
        in-flight batches on the device stream)."""
        return self.t_done - self.t_start


@dataclasses.dataclass(frozen=True)
class StepRecord:
    """Per-step accounting: what ran and whether the engine recompiled."""

    n_requests: int
    k: int
    bucket: int
    step_s: float  # dispatch -> results materialised on host
    compile_count: int  # executables after this step (ladder-wide total)
    dispatch_s: float = 0.0  # host time to form/pad/enqueue the batch
    # (the synchronous server folds dispatch into step_s and leaves this 0)
    level: int = 0  # degradation-ladder level the step was served at


@dataclasses.dataclass
class OverloadController:
    """Hysteretic overload detector driving the degradation ladder.

    Consumes the two load signals the servers already account — admission
    queue depth and head-of-queue wait (the queueing half of the PR-4
    queue/exec latency split) — and maintains a degradation ``level``:

    * **step up** after ``patience`` consecutive overloaded observations
      (depth >= ``high_depth`` or head wait >= ``high_wait_s``);
    * **step down** after ``cooldown`` consecutive calm observations
      (depth <= ``low_depth`` and head wait < ``high_wait_s / 2``).

    The two-sided hysteresis keeps the ladder from flapping at the
    boundary; levels clamp to ``[0, max_level]``.  Deterministic: the
    level is a pure function of the observation sequence.
    """

    max_level: int = 2
    high_depth: int = 32
    low_depth: int = 4
    high_wait_s: float = 0.05
    patience: int = 2
    cooldown: int = 2
    level: int = dataclasses.field(default=0, init=False)
    _hot: int = dataclasses.field(default=0, init=False, repr=False)
    _calm: int = dataclasses.field(default=0, init=False, repr=False)

    def update(self, depth: int, head_wait_s: float) -> int:
        """Feed one (queue depth, head-of-queue wait) observation; returns
        the level the next batch should be served at."""
        overloaded = depth >= self.high_depth or head_wait_s >= self.high_wait_s
        calm = depth <= self.low_depth and head_wait_s < self.high_wait_s / 2
        if overloaded:
            self._hot, self._calm = self._hot + 1, 0
        elif calm:
            self._hot, self._calm = 0, self._calm + 1
        else:
            self._hot = self._calm = 0
        if self._hot >= self.patience and self.level < self.max_level:
            self.level += 1
            self._hot = 0
        elif self._calm >= self.cooldown and self.level > 0:
            self.level -= 1
            self._calm = 0
        return self.level


class DegradationLadder:
    """Pre-warmed engines over one ``(x, index)`` at stepped-down budgets.

    Level 0 is the base engine; level ``l`` serves
    ``engine.policy.degraded(l)`` — reduced (alpha, beta, survivor_cap).
    Every level's recall floor is Theorem 2 recomputed for its budget
    (:func:`repro.core.theory.degraded_budget_bound`) from sampled
    subspace statistics (:func:`repro.core.theory.estimate_subspace_statistics`),
    so an answer served degraded carries a *quantified* guarantee.

    Reported floors are monotonised down the ladder
    (``bound(l) = min over levels <= l``): each level's bound is a valid
    lower bound for its own budget, and reporting the minimum keeps the
    ladder honest where the raw Theorem-2 term is not monotone in alpha
    (shrinking alpha widens the collision radius) — a server must never
    claim *more* recall because it is shedding work.

    :meth:`warmup` pre-compiles every level's ``(bucket, k)`` executables
    so stepping the ladder under load never retraces;
    ``compile_count`` sums the whole ladder for the zero-retrace
    invariant.
    """

    def __init__(
        self,
        engine: SuCoEngine,
        levels: int = 2,
        *,
        stats: tuple[float, float] | None = None,
        stats_seed: int = 0,
    ):
        if levels < 0:
            raise ValueError(f"ladder levels must be >= 0, got {levels}")
        self.engines: list[SuCoEngine] = [engine]
        for lv in range(1, levels + 1):
            self.engines.append(
                SuCoEngine(engine.x, engine.index, engine.policy.degraded(lv))
            )
        if stats is None:
            stats = theory.estimate_subspace_statistics(
                np.asarray(engine.x),  # jaxlint: sync-ok — one-time stats sample
                engine.index.spec.n_subspaces,
                seed=stats_seed,
            )
        self.m_stat, self.sigma_stat = float(stats[0]), float(stats[1])
        self._bounds: dict[tuple[int, int, int], float] = {}

    @property
    def max_level(self) -> int:
        return len(self.engines) - 1

    def engine_for(self, level: int) -> SuCoEngine:
        """The engine serving ``level`` (clamped to the ladder)."""
        return self.engines[min(max(level, 0), self.max_level)]

    def rebind(self) -> None:
        """Propagate the base engine's live ``(x, index)`` to every sibling.

        Levels 1+ were constructed over level 0's arrays; after an in-place
        mutation on the base (insert / delete) they must be re-pointed at
        the mutated arrays or degraded answers would be served from the
        pre-mutation corpus — including already-tombstoned ids.  Shapes and
        treedef are unchanged, so the siblings' warmed executables keep
        hitting (no retrace).
        """
        base = self.engines[0]
        for sib in self.engines[1:]:
            sib._rebind(
                base.x, base.index,
                n_live=base.n_live, next_slot=base._next_slot,
            )

    def quality_bound(self, level: int, k: int) -> float:
        """The monotonised Theorem-2 success floor at ``(level, k)``.

        Computed against the *live* point count, not the build-time one:
        inserts and tombstoned deletes move ``n``, and a floor quoted for
        a corpus size that no longer exists is not a guarantee.  The cache
        key carries ``n`` so mutation invalidates stale entries for free.
        """
        level = min(max(level, 0), self.max_level)
        base = self.engines[0]
        n = int(base.n_live)
        key = (level, k, n)
        if key not in self._bounds:
            ns = base.index.spec.n_subspaces
            self._bounds[key] = min(
                theory.degraded_budget_bound(
                    n, k, ns, self.m_stat, self.sigma_stat,
                    e.policy.alpha, e.policy.beta,
                )
                for e in self.engines[: level + 1]
            )
        return self._bounds[key]

    def warmup(
        self,
        batch_sizes: Sequence[int] | None = (1,),
        ks: Sequence[int] = (10,),
    ) -> int:
        """Pre-compile every level's executables; returns fresh compiles."""
        return sum(e.warmup(batch_sizes, ks) for e in self.engines)

    @property
    def compile_count(self) -> int:
        """Ladder-wide executable count (the zero-retrace accounting unit)."""
        return sum(e.compile_count for e in self.engines)


class AnnServer:
    """Continuous micro-batching over a warmed :class:`SuCoEngine`.

    Mirrors :class:`repro.launch.serve.Server`'s slot design: ``max_batch``
    is the slot count, the queue refills the batch at each step boundary.
    Requests with different ``k`` cannot share an executable, so a step
    serves the ``k`` of the most urgent request (oldest deadline, FIFO on
    ties) and defers the rest — arrival order is preserved within every
    ``k`` class and across deferrals, and with no deadlines in play the
    schedule is exactly FIFO-first-``k``.

    Resilience knobs (all optional; the defaults are the pre-resilience
    behavior):

    * ``max_queue`` — bounded admission: ``submit`` beyond it sheds the
      request (completes-with-error, ``shed=True``) instead of queueing.
    * ``ladder`` + ``controller`` — overload-driven degraded mode; see
      :class:`DegradationLadder` / :class:`OverloadController`.  With a
      ladder but no controller the level is pinned at ``self.level``
      (settable — the forced degrade/recover cycle the benchmarks drive).
    * ``max_retries`` / ``backoff_s`` — transient dispatch errors are
      retried with jittered backoff before falling back to per-request
      isolation.  ``sleep`` is injectable so fault-injection replays
      (``serve/chaos.py``) stay on a virtual clock.
    """

    def __init__(
        self,
        engine: SuCoEngine,
        max_batch: int = 64,
        clock: Callable[[], float] = time.perf_counter,
        *,
        max_queue: int | None = None,
        ladder: DegradationLadder | None = None,
        controller: OverloadController | None = None,
        max_retries: int = 1,
        backoff_s: float = 0.002,
        seed: int = 0,
        sleep: Callable[[float], None] = time.sleep,
        durability=None,
    ):
        self.engine = engine
        self.max_batch = max_batch
        self.clock = clock
        # A repro.serve.durability.Durability (or None): when set, every
        # acknowledged mutation is WAL-logged before the call returns.
        self.durability = durability
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.max_queue = max_queue
        self.ladder = ladder
        self.controller = controller
        if controller is not None and ladder is not None:
            controller.max_level = min(controller.max_level, ladder.max_level)
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.sleep = sleep
        self.level = 0  # current ladder level (pinned when controller is None)
        self._rng = np.random.default_rng(seed)  # backoff jitter only
        self.queue: deque[AnnRequest] = deque()
        self.completed: list[AnnRequest] = []
        self.steps: list[StepRecord] = []

    # ---- admission -------------------------------------------------------

    def _validate(self, req: AnnRequest) -> str | None:
        """Admission-time validation: reject malformed requests here, with a
        per-request error, instead of failing a whole batch at dispatch."""
        d = self.engine.index.spec.d
        # k is bounded by the LIVE point count: tombstoned slots can never
        # appear in an answer, so admitting k > n_live would promise more
        # distinct neighbours than exist.
        n = int(getattr(self.engine, "n_live", self.engine.x.shape[0]))
        q = np.asarray(req.query)  # jaxlint: sync-ok — host payload
        if q.ndim != 1 or q.shape[0] != d or not np.issubdtype(q.dtype, np.number):
            return f"query must be ({d},), got shape {q.shape} dtype {q.dtype}"
        if not np.isfinite(q).all():
            return "query contains NaN/Inf"
        if not 1 <= int(req.k) <= n:
            return f"k={req.k} must be in [1, n={n}]"
        return None

    def submit(self, req: AnnRequest) -> bool:
        """Admit one request; returns False if it was rejected (malformed
        input or admission queue full), in which case it is already in
        ``completed`` with ``error`` set."""
        now = self.clock()
        req.t_submit = now
        if req.deadline_s is not None:
            req.t_deadline = now + req.deadline_s
        err = self._validate(req)
        if err is not None:
            req.error, req.t_done = err, now
            self.completed.append(req)
            return False
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            req.shed = True
            req.error = f"shed: admission queue full (max_queue={self.max_queue})"
            req.t_done = now
            self.completed.append(req)
            return False
        self.queue.append(req)
        return True

    def submit_many(self, reqs: Sequence[AnnRequest]) -> int:
        """Admit a request sequence; returns how many were accepted."""
        return sum(self.submit(r) for r in reqs)

    # ---- scheduling ------------------------------------------------------

    def _est_exec_s(self) -> float:
        """Recent execution-latency estimate (median of the last few steps'
        exec time) — the feasibility signal for deadline expiry.  0.0 with
        no history, so expiry starts vacuous and tightens as steps land."""
        recent = [s.step_s for s in self.steps[-8:] if s.n_requests > 0]
        return float(np.median(recent)) if recent else 0.0

    def _expire_overdue(self, now: float) -> int:
        """Expire queued requests that cannot finish in time: their deadline
        precedes ``now`` plus the execution estimate.  Expired requests
        complete-with-error (``expired=True``) without burning a slot."""
        if not any(r.t_deadline < math.inf for r in self.queue):
            return 0
        horizon = now + self._est_exec_s()
        live: deque[AnnRequest] = deque()
        n_expired = 0
        for r in self.queue:
            if r.t_deadline < horizon:
                r.expired = True
                r.error = (
                    f"expired: deadline t={r.t_deadline:.6f} unreachable at "
                    f"dispatch (now={now:.6f})"
                )
                r.t_done = now
                self.completed.append(r)
                n_expired += 1
            else:
                live.append(r)
        self.queue = live
        return n_expired

    def _form_batch(self) -> tuple[list[AnnRequest], int]:
        """Pop the next same-``k`` micro-batch off the admission queue,
        oldest-deadline-first.

        The most urgent request (smallest ``t_deadline``, queue rank on
        ties — so deadline-free traffic stays FIFO) leads and fixes the
        batch's ``k``; other-``k`` requests keep their queue rank for a
        later step.
        """
        order = sorted(
            range(len(self.queue)), key=lambda i: (self.queue[i].t_deadline, i)
        )
        k = self.queue[order[0]].k
        taken: set[int] = set()
        batch: list[AnnRequest] = []
        for i in order:
            if len(batch) >= self.max_batch:
                break
            if self.queue[i].k == k:
                batch.append(self.queue[i])
                taken.add(i)
        self.queue = deque(r for i, r in enumerate(self.queue) if i not in taken)
        return batch, k

    def _serving_level(self, now: float) -> int:
        """The ladder level for the next batch: controller-driven when one
        is installed, else the pinned ``self.level``."""
        if self.controller is not None:
            head_wait = now - min((r.t_submit for r in self.queue), default=now)
            self.level = self.controller.update(len(self.queue), head_wait)
        if self.ladder is not None:
            self.level = min(self.level, self.ladder.max_level)
        elif self.level != 0:
            self.level = 0  # no ladder: nothing to degrade to
        return self.level

    def _engine_for(self, level: int) -> SuCoEngine:
        return self.ladder.engine_for(level) if self.ladder is not None else self.engine

    def _quality_bound(self, level: int, k: int) -> float | None:
        return self.ladder.quality_bound(level, k) if self.ladder is not None else None

    @property
    def executables(self) -> int:
        """Compiled executables across the whole serving surface (every
        ladder level when one is installed) — the quantity that must stay
        flat after warmup for the zero-retrace invariant."""
        return (
            self.ladder.compile_count
            if self.ladder is not None
            else self.engine.compile_count
        )

    # ---- live mutation ---------------------------------------------------

    def insert(self, x_new, *, keys=None) -> np.ndarray:
        """Insert points into the serving engine between steps; returns the
        assigned slot ids.  Ladder siblings are re-pointed at the mutated
        arrays so degraded answers see the same live corpus.  With a
        durability root attached the insert is WAL-logged (with its
        external ``keys``, if the caller tracks any) before the return —
        the acknowledgement implies the record is framed on disk."""
        slots = self.engine.insert(x_new)
        if self.ladder is not None:
            self.ladder.rebind()
        if self.durability is not None:
            self.durability.log_insert(x_new, slots, keys=keys)
        return slots

    def delete(self, ids) -> int:
        """Tombstone ids in the serving engine between steps; returns how
        many were newly deleted.  From the next dispatched batch on, no
        answer — base or degraded — can contain a tombstoned id."""
        n_newly = self.engine.delete(ids)
        if self.ladder is not None:
            self.ladder.rebind()
        if self.durability is not None:
            self.durability.log_delete(ids)
        return n_newly

    def swap(self, engine: SuCoEngine, *, ladder: DegradationLadder | None = None) -> None:
        """Hand the whole serving surface over to a warmed successor.

        ``engine`` replaces the base engine via :meth:`SuCoEngine.swap`
        (in-place adoption — object identity is preserved, so everything
        holding ``self.engine`` cuts over atomically).  When a degradation
        ladder is installed a successor ``ladder`` built over ``engine``
        must be supplied, warmed level-for-level; every level's warm
        contract is checked *before* any level is mutated, so a failed
        swap leaves the server fully on the old surface.  Queued requests
        are untouched — the next ``step`` dispatches on the successor.
        """
        if self.ladder is not None:
            if ladder is None:
                raise ValueError(
                    "server has a degradation ladder installed — pass a "
                    "warmed successor ladder built over the new engine"
                )
            if ladder.engines[0] is not engine:
                raise ValueError(
                    "successor ladder must be built over the successor "
                    "engine (ladder.engines[0] is not the engine passed)"
                )
            if len(ladder.engines) != len(self.ladder.engines):
                raise ValueError(
                    f"successor ladder has {len(ladder.engines)} levels, "
                    f"serving ladder has {len(self.ladder.engines)} — swap "
                    "level-for-level or rebuild the server"
                )
            pairs = list(zip(self.ladder.engines, ladder.engines))
        else:
            pairs = [(self.engine, engine)]
        # Check every level's warm contract before mutating any: a swap is
        # all-or-nothing across the ladder.
        for lv, (old, new) in enumerate(pairs):
            missing = old._buckets_seen - new._buckets_seen
            if missing:
                raise ValueError(
                    f"swap target level {lv} is not warmed over the live "
                    f"traffic mix — missing (bucket, k) executables "
                    f"{sorted(missing)}; warm the successor first"
                )
        for old, new in pairs:
            old.swap(new)
        if self.ladder is not None:
            self.ladder.m_stat = ladder.m_stat
            self.ladder.sigma_stat = ladder.sigma_stat
            self.ladder._bounds = {}
        if self.durability is not None:
            # A bare swap installs state the WAL cannot replay; the
            # durability layer checkpoints it (suppressed when the swap is
            # part of a manager-driven, WAL-replayable reindex).
            self.durability.note_swap()

    # ---- fault isolation -------------------------------------------------

    def _query_with_retry(self, engine: SuCoEngine, batch, q, k: int):
        """One batch dispatch, retried ``max_retries`` times with jittered
        backoff on transient (non-ValueError) failures."""
        attempts = self.max_retries + 1
        for attempt in range(attempts):
            try:
                return engine.query(q, k=k)
            except ValueError:
                raise  # malformed input: retrying cannot help
            except Exception:
                if attempt + 1 >= attempts:
                    raise
                for r in batch:
                    r.retries += 1
                self.sleep(self.backoff_s * (0.5 + self._rng.random()))

    def _isolate(self, engine: SuCoEngine, batch, k: int, level: int) -> None:
        """Per-request fallback after a batch dispatch failed its retries:
        serve each request individually so one poison query fails alone."""
        qb = self._quality_bound(level, k)
        for r in batch:
            try:
                q1 = np.asarray(r.query)  # jaxlint: sync-ok — host payload
                res = engine.query(q1, k=k)
                r.ids = np.asarray(res.ids)  # jaxlint: sync-ok — failure-isolation path
                r.dists = np.asarray(res.dists)  # jaxlint: sync-ok — failure-isolation path
                r.degrade_level, r.quality_bound = level, qb
            except Exception as e:
                r.error = f"{type(e).__name__}: {e}"
            r.t_done = self.clock()

    # ---- step loop -------------------------------------------------------

    def step(self) -> list[AnnRequest]:
        """Run one micro-batch; returns the requests it completed."""
        now = self.clock()
        self._expire_overdue(now)
        if not self.queue:
            return []
        level = self._serving_level(now)
        engine = self._engine_for(level)
        batch, k = self._form_batch()

        t0 = self.clock()
        for r in batch:
            r.t_start = t0
        qs = [np.asarray(r.query) for r in batch]  # jaxlint: sync-ok — host payload
        try:
            res = self._query_with_retry(engine, batch, np.stack(qs), k)
            ids = np.asarray(res.ids)  # jaxlint: sync-ok — sync serving step
            dists = np.asarray(res.dists)  # jaxlint: sync-ok
            t1 = self.clock()
            qb = self._quality_bound(level, k)
            for i, r in enumerate(batch):
                r.ids, r.dists, r.t_done = ids[i], dists[i], t1
                r.degrade_level, r.quality_bound = level, qb
        except ValueError as e:
            # A malformed batch (should be impossible past submit-time
            # validation) completes-with-error without sinking the server.
            t1 = self.clock()
            for r in batch:
                r.error, r.t_done = str(e), t1
        except Exception:
            # Retries exhausted: isolate per request.
            self._isolate(engine, batch, k, level)
            t1 = self.clock()
        self.completed.extend(batch)
        self.steps.append(
            StepRecord(
                n_requests=len(batch),
                k=k,
                bucket=batch_bucket(len(batch), engine.policy.batch_buckets),
                step_s=t1 - t0,
                compile_count=self.executables,
                level=level,
            )
        )
        return batch

    def run_until_drained(self) -> list[AnnRequest]:
        while self.queue:
            self.step()
        return self.completed


@dataclasses.dataclass
class _Inflight:
    """A dispatched-but-unmaterialised micro-batch riding the device stream."""

    batch: list[AnnRequest]
    k: int
    result: QueryResult
    t_dispatch: float
    dispatch_s: float
    level: int = 0


class AsyncAnnServer(AnnServer):
    """Pipelined continuous micro-batching: dispatch overlaps execution.

    The double-buffered step loop the synchronous server cannot express:
    ``step`` forms, pads and *enqueues* the next micro-batch — jax
    dispatch is asynchronous, so the call returns while the previous
    batch still executes — and results are materialised
    (``np.asarray``, the only blocking point) only once ``depth``
    micro-batches are in flight or the queue drains.  With the default
    ``depth=2`` the host assembles batch t+1 while batch t executes;
    the device stream never waits on host-side batch formation.

    Completion order equals dispatch order (the in-flight window is a
    FIFO), so results are a permutation of the synchronous server's only
    across the interleaving of ``k`` classes — per request the answer is
    identical.  Malformed requests are rejected at ``submit``; a dispatch
    that still fails is retried with backoff and then isolated per
    request, without touching the healthy batches already in flight, and
    a batch whose *materialisation* fails completes-with-error alone.
    Deadlines, admission control, and the degradation ladder behave as in
    :class:`AnnServer` (the level is sampled at dispatch and rides the
    in-flight record).
    """

    def __init__(
        self,
        engine: SuCoEngine,
        max_batch: int = 64,
        clock: Callable[[], float] = time.perf_counter,
        *,
        depth: int = 2,
        **resilience,
    ):
        super().__init__(engine, max_batch, clock, **resilience)
        if depth < 1:
            raise ValueError(f"pipeline depth must be >= 1, got {depth}")
        self.depth = depth
        self._inflight: deque[_Inflight] = deque()

    @property
    def inflight(self) -> int:
        """Micro-batches dispatched but not yet materialised."""
        return len(self._inflight)

    def _dispatch(self) -> None:
        """Form the next micro-batch and enqueue it on the device (non-blocking)."""
        now = self.clock()
        level = self._serving_level(now)
        engine = self._engine_for(level)
        batch, k = self._form_batch()
        t0 = self.clock()
        for r in batch:
            r.t_start = t0
        qs = [np.asarray(r.query) for r in batch]  # jaxlint: sync-ok — host payload
        try:
            res = self._query_with_retry(engine, batch, np.stack(qs), k)
        except ValueError as e:
            # Validation failures surface here, before anything reaches the
            # device: the malformed micro-batch completes-with-error and the
            # in-flight healthy batches are untouched.
            t1 = self.clock()
            for r in batch:
                r.error, r.t_done = str(e), t1
            self.completed.extend(batch)
            self.steps.append(
                StepRecord(
                    n_requests=len(batch),
                    k=k,
                    bucket=batch_bucket(len(batch), engine.policy.batch_buckets),
                    step_s=t1 - t0,
                    compile_count=self.executables,
                    dispatch_s=t1 - t0,
                    level=level,
                )
            )
            return
        except Exception:
            # Retries exhausted: isolate per request, in front of the
            # in-flight window (these requests never reached the device).
            self._isolate(engine, batch, k, level)
            t1 = self.clock()
            self.completed.extend(batch)
            self.steps.append(
                StepRecord(
                    n_requests=len(batch),
                    k=k,
                    bucket=batch_bucket(len(batch), engine.policy.batch_buckets),
                    step_s=t1 - t0,
                    compile_count=self.executables,
                    dispatch_s=t1 - t0,
                    level=level,
                )
            )
            return
        self._inflight.append(
            _Inflight(batch, k, res, t0, dispatch_s=self.clock() - t0, level=level)
        )

    def _retire(self) -> list[AnnRequest]:
        """Materialise the oldest in-flight batch (blocks until it is done)."""
        fl = self._inflight.popleft()
        try:
            # The ONE intentional blocking point of the async hot path:
            # retiring the oldest in-flight batch materialises its results.
            ids = np.asarray(fl.result.ids)  # jaxlint: sync-ok — the retire point
            dists = np.asarray(fl.result.dists)  # jaxlint: sync-ok
            t1 = self.clock()
            qb = self._quality_bound(fl.level, fl.k)
            for i, r in enumerate(fl.batch):
                r.ids, r.dists, r.t_done = ids[i], dists[i], t1
                r.degrade_level, r.quality_bound = fl.level, qb
        except Exception as e:
            # A batch that poisons materialisation fails alone; batches
            # behind it in the window are unaffected.
            t1 = self.clock()
            for r in fl.batch:
                r.error, r.t_done = f"{type(e).__name__}: {e}", t1
        self.completed.extend(fl.batch)
        self.steps.append(
            StepRecord(
                n_requests=len(fl.batch),
                k=fl.k,
                bucket=batch_bucket(
                    len(fl.batch),
                    self._engine_for(fl.level).policy.batch_buckets,
                ),
                step_s=t1 - fl.t_dispatch,
                compile_count=self.executables,
                dispatch_s=fl.dispatch_s,
                level=fl.level,
            )
        )
        return fl.batch

    def step(self) -> list[AnnRequest]:
        """Dispatch the next micro-batch; retire batches past the window.

        Returns the requests *completed* this step (possibly none — the
        freshly dispatched batch completes on a later step).
        """
        before = len(self.completed)
        self._expire_overdue(self.clock())
        if self.queue:
            self._dispatch()
        while len(self._inflight) > self.depth:
            self._retire()
        return self.completed[before:]

    def flush(self) -> list[AnnRequest]:
        """Materialise every in-flight batch (result delivery barrier)."""
        done: list[AnnRequest] = []
        while self._inflight:
            done.extend(self._retire())
        return done

    def swap(self, engine: SuCoEngine, *, ladder: DegradationLadder | None = None) -> None:
        """Retire every in-flight batch on the old engine, then cut over.

        In-flight device buffers would stay valid across the cutover (jax
        arrays are immutable), but retiring them first keeps the handoff
        contract simple: every answer delivered after ``swap`` returns was
        computed on the successor.  Queued-but-undispatched requests ride
        through and dispatch on the new engine — nothing is dropped.
        """
        self.flush()
        super().swap(engine, ladder=ladder)

    def run_until_drained(self) -> list[AnnRequest]:
        while self.queue:
            self.step()
        self.flush()
        return self.completed


def latency_summary(requests: Sequence[AnnRequest]) -> dict:
    """QPS + latency percentiles for a completed request set.

    End-to-end latency is split into its queueing (admission -> dispatch)
    and execution (dispatch -> materialisation) components so pipelined
    and synchronous runs can be compared on where requests spend time,
    not just on the total.  The resilience outcomes are reported
    distinctly: shed (admission rejected), expired (deadline unreachable),
    failed (dispatch error), and degraded answers with the worst
    Theorem-2 ``quality_bound`` any answer carried.  ``deadline_hit_rate``
    is over the requests that had a deadline (1.0 when none did).
    """
    done = [r for r in requests if r.done]
    n_shed = sum(1 for r in requests if r.shed)
    n_expired = sum(1 for r in requests if r.expired)
    n_failed = sum(
        1 for r in requests if r.error is not None and not (r.shed or r.expired)
    )
    n_degraded = sum(1 for r in done if r.degrade_level > 0)
    # Hit rate is over ADMITTED deadlined requests: a shed request was
    # rejected explicitly at admission (reported as n_shed) — the point of
    # admission control is converting silent deadline misses into early
    # rejections, so sheds must not double-count as misses.  Expired
    # requests were admitted and do count as misses.
    with_deadline = [
        r for r in requests if r.t_deadline < math.inf and not r.shed
    ]
    deadline_hit_rate = (
        sum(1 for r in with_deadline if r.hit_deadline) / len(with_deadline)
        if with_deadline
        else 1.0
    )
    bounds = [r.quality_bound for r in done if r.quality_bound is not None]
    resilience = dict(
        n_shed=n_shed,
        n_expired=n_expired,
        n_failed=n_failed,
        n_degraded=n_degraded,
        degraded_fraction=n_degraded / len(done) if done else 0.0,
        deadline_hit_rate=deadline_hit_rate,
        quality_bound_min=float(min(bounds)) if bounds else 1.0,
    )
    if not done:
        # Zeroed summary with the full key set: consumers (the CLI report,
        # dashboards) index these keys unconditionally, and np.percentile on
        # an empty array raises.
        return dict(
            n_requests=0,
            qps=0.0,
            p50_ms=0.0,
            p99_ms=0.0,
            mean_ms=0.0,
            max_ms=0.0,
            queue_p50_ms=0.0,
            queue_p99_ms=0.0,
            exec_p50_ms=0.0,
            exec_p99_ms=0.0,
            **resilience,
        )
    lat = np.asarray([r.latency_s for r in done])
    queue = np.asarray([r.queue_s for r in done])
    execu = np.asarray([r.exec_s for r in done])
    wall = max(r.t_done for r in done) - min(r.t_submit for r in done)
    return dict(
        n_requests=len(done),
        qps=len(done) / wall if wall > 0 else float("inf"),
        p50_ms=float(np.percentile(lat, 50) * 1e3),
        p99_ms=float(np.percentile(lat, 99) * 1e3),
        mean_ms=float(lat.mean() * 1e3),
        max_ms=float(lat.max() * 1e3),
        queue_p50_ms=float(np.percentile(queue, 50) * 1e3),
        queue_p99_ms=float(np.percentile(queue, 99) * 1e3),
        exec_p50_ms=float(np.percentile(execu, 50) * 1e3),
        exec_p99_ms=float(np.percentile(execu, 99) * 1e3),
        **resilience,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--d", type=int, default=32)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sync", action="store_true",
                    help="use the synchronous step loop (default: pipelined)")
    ap.add_argument("--depth", type=int, default=2,
                    help="pipelined in-flight window (ignored with --sync)")
    args = ap.parse_args()

    from repro.data import make_dataset

    ds = make_dataset("gaussian_mixture", args.n, args.d, m=1, k=10, seed=args.seed)
    engine = SuCoEngine.build(
        ds.x,
        SuCoConfig(n_subspaces=8, sqrt_k=16, kmeans_iters=4, seed=args.seed),
        policy=EnginePolicy(alpha=0.05, beta=0.02),
    )
    rng = np.random.default_rng(args.seed)
    # cover every bucket a <= max_batch micro-batch can land in
    engine.warmup(batch_sizes=range(1, args.max_batch + 1), ks=(5, 10))
    if args.sync:
        server = AnnServer(engine, max_batch=args.max_batch)
    else:
        server = AsyncAnnServer(engine, max_batch=args.max_batch, depth=args.depth)
    server.submit_many(
        AnnRequest(i, ds.x[rng.integers(0, args.n)], k=int(rng.choice([5, 10])))
        for i in range(args.requests)
    )
    done = server.run_until_drained()
    s = latency_summary(done)
    print(
        f"[ann-serve{'' if args.sync else '-async'}] "
        f"{s['n_requests']} requests in {len(server.steps)} steps: "
        f"{s['qps']:.1f} qps, p50 {s['p50_ms']:.1f} ms, p99 {s['p99_ms']:.1f} ms "
        f"(queue p50 {s['queue_p50_ms']:.1f} / exec p50 {s['exec_p50_ms']:.1f}), "
        f"executables {engine.compile_count}"
    )


if __name__ == "__main__":
    main()
