"""Deterministic fault injection for the ANN serving stack.

Chaos testing of :mod:`repro.serve.ann` without wall clocks or real
failures: a :class:`VirtualClock` replaces ``time.perf_counter`` (servers
take ``clock``/``sleep`` callables for exactly this), and a
:class:`ChaosEngine` wraps a real :class:`~repro.core.suco.SuCoEngine`,
drawing every injected fault — engine exceptions, latency spikes — from
one seeded ``numpy`` Generator whose consumption order is fixed by the
replay's event order.  Replaying the same request trace with the same
:class:`ChaosConfig` therefore reproduces the *identical* schedule:
the same requests shed, expired, degraded, failed — byte-for-byte
(:func:`replay` returns the outcome sets as frozensets so tests compare
them directly).

Injectors (all seeded, all off by default):

* **engine exception** — ``p_engine_error`` chance a dispatch raises
  :class:`ChaosError` (exercises retry-with-backoff + per-request
  isolation);
* **latency spike** — ``p_latency_spike`` chance a dispatch takes
  ``latency_spike_s`` extra virtual seconds (exercises deadline expiry);
* **malformed query** — :func:`flood_trace` poisons a fraction of
  requests with NaN (exercises submit-time validation);
* **queue flood** — :func:`flood_trace` draws arrivals faster than the
  configured service time (exercises admission control + the
  degradation ladder);
* **shard death** — :func:`kill_pool_engine` makes one per-k engine of a
  :class:`~repro.distributed.engine.ShardedEnginePool` raise on every
  query (exercises k-class rebinding);
* **process death** — :class:`CrashInjector` raises :class:`CrashPoint`
  at any one of the instrumented durability boundaries
  (:data:`CRASH_POINTS`: WAL append/fsync, snapshot write/rename, log
  truncation, the off-thread re-index prepare); :func:`recovery_drill`
  kills a durable stack there, recovers it from disk, and verifies the
  no-acknowledged-loss / bit-identical-state contract of
  :mod:`repro.serve.durability`.

Usage sketch (see ``tests/test_chaos.py`` / ``benchmarks/serve_chaos.py``)::

    clock = VirtualClock()
    chaos = ChaosEngine(engine, ChaosConfig(seed=0, p_engine_error=0.05),
                        clock=clock)
    server = AsyncAnnServer(chaos, clock=clock, sleep=clock.advance,
                            max_queue=64, ladder=ladder,
                            controller=OverloadController())
    report = replay(server, flood_trace(...), clock)
"""

from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Callable, Sequence

import numpy as np

from repro.serve.ann import AnnRequest, AnnServer, latency_summary

__all__ = [
    "ChaosError",
    "VirtualClock",
    "ChaosConfig",
    "ChaosEngine",
    "wrap_ladder",
    "ReplayReport",
    "flood_trace",
    "replay",
    "kill_pool_engine",
    "CrashPoint",
    "CrashInjector",
    "CRASH_POINTS",
    "DrillStep",
    "DrillReport",
    "drill_steps",
    "recovery_drill",
]


class ChaosError(RuntimeError):
    """The injected transient engine failure (never raised by real code)."""


class VirtualClock:
    """A deterministic clock: time moves only when ``advance`` is called.

    Doubles as the server's ``clock`` (it is callable) and — via
    ``advance`` — its ``sleep``, so retry backoff consumes virtual time
    instead of stalling the test suite.
    """

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"time cannot go backwards (dt={dt})")
        self.t += float(dt)
        return self.t


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Seeded fault-injection plan for one replay."""

    seed: int = 0
    service_s: float = 0.001  # virtual execution time per dispatch
    p_engine_error: float = 0.0  # chance a dispatch raises ChaosError
    p_latency_spike: float = 0.0  # chance a dispatch stalls extra
    latency_spike_s: float = 0.05  # the stall

    def __post_init__(self):
        for name in ("p_engine_error", "p_latency_spike"):
            p = getattr(self, name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {p}")


class ChaosEngine:
    """A :class:`~repro.core.suco.SuCoEngine` proxy that injects faults.

    Every ``query`` advances the virtual clock by ``service_s``, then
    draws exactly two uniforms from the shared schedule — one for the
    latency spike, one for the engine error — so the fault sequence is a
    pure function of ``(seed, dispatch order)``; the replay's event loop
    fixes the dispatch order, making whole replays reproducible.
    Everything else (``policy``, ``compile_count``, ``index`` …)
    delegates to the wrapped engine, so servers and ladders treat the
    proxy as the real thing.
    """

    def __init__(
        self,
        engine,
        config: ChaosConfig,
        clock: VirtualClock,
        *,
        rng: np.random.Generator | None = None,
    ):
        self._engine = engine
        self._config = config
        self._clock = clock
        # An injected rng lets several proxies (e.g. every level of a
        # degradation ladder, via wrap_ladder) consume ONE fault schedule,
        # keeping determinism a property of global dispatch order.
        self._rng = np.random.default_rng(config.seed) if rng is None else rng
        self.n_dispatches = 0
        self.n_errors = 0
        self.n_spikes = 0

    def query(self, q, k: int):
        c = self._config
        self.n_dispatches += 1
        # Fixed draw count per dispatch keeps the schedule aligned across
        # replays even when an earlier injector fires.
        u_spike, u_err = self._rng.random(2)
        self._clock.advance(c.service_s)
        if u_spike < c.p_latency_spike:
            self.n_spikes += 1
            self._clock.advance(c.latency_spike_s)
        if u_err < c.p_engine_error:
            self.n_errors += 1
            raise ChaosError(
                f"injected engine failure (dispatch #{self.n_dispatches})"
            )
        return self._engine.query(q, k=k)

    def __getattr__(self, name):
        return getattr(self._engine, name)


def wrap_ladder(ladder, config: ChaosConfig, clock: VirtualClock):
    """Wrap every engine of a :class:`~repro.serve.ann.DegradationLadder`
    in :class:`ChaosEngine` proxies sharing ONE fault schedule.

    A server with a ladder routes every batch through
    ``ladder.engine_for(level)`` — wrapping only the base engine would
    leave the degraded paths chaos-free.  The proxies share one seeded
    Generator, so the fault sequence stays a pure function of the global
    dispatch order regardless of which level serves each batch.  Returns
    the ladder (mutated in place); pass ``ladder.engines[0]`` as the
    server's engine so the level-0 path is the same proxy.
    """
    rng = np.random.default_rng(config.seed)
    ladder.engines = [
        ChaosEngine(e, config, clock, rng=rng) for e in ladder.engines
    ]
    return ladder


@dataclasses.dataclass(frozen=True)
class ReplayReport:
    """Outcome of one chaos replay, keyed by request id.

    The id sets are frozensets so determinism tests compare replays with
    ``==``; ``summary`` is :func:`repro.serve.ann.latency_summary` over
    every request of the trace and ``retraces`` is the executable-count
    growth across the replay (0 = the zero-retrace invariant held under
    chaos).
    """

    completed: frozenset[int]
    shed: frozenset[int]
    expired: frozenset[int]
    failed: frozenset[int]
    degraded: frozenset[int]
    max_level: int
    summary: dict
    retraces: int

    @property
    def outcome_sets(self) -> tuple[frozenset[int], ...]:
        """The determinism-test tuple: identical across equal replays."""
        return (self.completed, self.shed, self.expired, self.failed, self.degraded)


def flood_trace(
    n_requests: int,
    d: int,
    *,
    interarrival_s: float = 0.0002,
    deadline_s: float | None = 0.05,
    ks: Sequence[int] = (10,),
    p_malformed: float = 0.0,
    seed: int = 0,
    queries: np.ndarray | None = None,
) -> list[tuple[float, AnnRequest]]:
    """A seeded ``(arrival_s, request)`` trace for :func:`replay`.

    Arrivals are uniformly spaced at ``interarrival_s`` — set it below the
    chaos ``service_s`` (times the batch fill) to flood the admission
    queue.  A ``p_malformed`` fraction of requests is poisoned with NaN
    in one coordinate, exercising submit-time validation inside otherwise
    healthy traffic.  Queries are drawn from ``queries`` rows when given
    (so answers are comparable to a clean run), else standard normal.
    """
    rng = np.random.default_rng(seed)
    trace: list[tuple[float, AnnRequest]] = []
    for i in range(n_requests):
        if queries is not None:
            row = queries[int(rng.integers(0, len(queries)))]
            q = np.array(row, dtype=np.float32)  # jaxlint: sync-ok — host trace rows
        else:
            q = rng.standard_normal(d).astype(np.float32)
        if p_malformed > 0.0 and rng.random() < p_malformed:
            q[int(rng.integers(0, d))] = np.nan
        k = int(ks[int(rng.integers(0, len(ks)))])
        trace.append(
            (i * interarrival_s, AnnRequest(i, q, k=k, deadline_s=deadline_s))
        )
    return trace


def replay(
    server: AnnServer,
    trace: Sequence[tuple[float, AnnRequest]],
    clock: VirtualClock,
) -> ReplayReport:
    """Drive ``server`` through an arrival trace on the virtual clock.

    Event loop: admit every request whose arrival time has passed, then
    run one server step (which advances the clock through the chaos
    engine's service time); when the server is idle and the next arrival
    is in the future, jump the clock to it.  The loop — and therefore the
    fault schedule consumed from the chaos engine — is a deterministic
    function of (trace, chaos seed, server configuration).

    A trace entry may carry a *callable* instead of a request: it is
    invoked as ``event(server)`` at its scheduled time — the hook the
    mutate-while-serving tests use to script inserts, deletes, and warm
    handoffs between dispatches — and is excluded from the request
    accounting (``summary`` and the outcome sets cover requests only).
    """
    if any(t1 > t2 for (t1, _), (t2, _) in zip(trace, trace[1:])):
        raise ValueError("trace must be sorted by arrival time")
    exe_before = server.executables
    i = 0
    while True:
        while i < len(trace) and trace[i][0] <= clock():
            ev = trace[i][1]
            if callable(ev):
                ev(server)  # scripted mutation / handoff action
            else:
                server.submit(ev)
            i += 1
        if server.queue:
            server.step()
        elif getattr(server, "inflight", 0):
            server.flush()  # nothing left to dispatch right now: drain
        elif i < len(trace):
            clock.advance(trace[i][0] - clock())
        else:
            break
    reqs = [r for _, r in trace if not callable(r)]
    done = [r for r in reqs if r.done]
    return ReplayReport(
        completed=frozenset(r.rid for r in done),
        shed=frozenset(r.rid for r in reqs if r.shed),
        expired=frozenset(r.rid for r in reqs if r.expired),
        failed=frozenset(
            r.rid for r in reqs if r.error is not None and not (r.shed or r.expired)
        ),
        degraded=frozenset(r.rid for r in done if r.degrade_level > 0),
        max_level=max((r.degrade_level for r in done), default=0),
        summary=latency_summary(reqs),
        retraces=server.executables - exe_before,
    )


# ---------------------------------------------------------------------------
# Crash-point injection + recovery drills (the durability counterpart of the
# fault injectors above — see repro.serve.durability / docs/durability.md)
# ---------------------------------------------------------------------------


class CrashPoint(BaseException):
    """The injected process death.  A ``BaseException`` on purpose: real
    crashes don't care about ``except Exception`` cleanup — only state
    already on disk survives, which is exactly what the drill tests."""


#: Every instrumented write/rename/fsync boundary in the durability layer.
#: ``Durability`` / ``WriteAheadLog`` call ``injector.reach(point)`` at each;
#: the recovery drill kills the stack at every one in turn.
CRASH_POINTS: tuple[str, ...] = (
    "wal.append.pre",  # record not yet written (mutation applied, un-acked)
    "wal.append.torn",  # half a frame on disk — the torn-tail case
    "wal.append.post-write",  # frame fully written, ack never returned
    "wal.fsync.post",  # record storage-durable, ack never returned
    "snapshot.pre",  # before the checkpoint starts
    "snapshot.post-write",  # .writing staged, final name not yet replaced
    "snapshot.post-rename",  # snapshot live, WAL not yet truncated
    "wal.truncate.post-write",  # truncated log staged as .tmp
    "wal.truncate.post-rename",  # truncated log live, handle not reopened
    "reindex.mid-prepare",  # off-thread re-cluster died mid-build
)


class CrashInjector:
    """Arms one :data:`CRASH_POINTS` name and raises :class:`CrashPoint`
    the first time the durability layer reaches it.  ``reached`` records
    every boundary crossed (armed or not) — the coverage ledger the drill
    sweep uses to prove each point actually fires."""

    def __init__(self, armed: str | None = None):
        self.armed = armed
        self.fired = False
        self.reached: list[str] = []

    def arm(self, point: str) -> "CrashInjector":
        if point not in CRASH_POINTS:
            raise ValueError(f"unknown crash point {point!r}")
        self.armed = point
        self.fired = False
        return self

    def reach(self, point: str) -> None:
        self.reached.append(point)
        if self.armed == point and not self.fired:
            self.fired = True
            raise CrashPoint(point)


@dataclasses.dataclass(frozen=True)
class DrillStep:
    """One scripted action of a recovery drill.

    ``kind``: ``"insert"`` (payload = rows), ``"delete"`` (payload =
    external keys), ``"reindex"``, ``"snapshot"``, ``"flush"`` (the
    group-commit, driven synchronously so drills stay deterministic).
    """

    kind: str
    payload: np.ndarray | None = None

    @property
    def records(self) -> int:
        """WAL records this step appends when fully acknowledged."""
        return 1 if self.kind in ("insert", "delete", "reindex") else 0


def drill_steps(d: int, *, seed: int = 0) -> list[DrillStep]:
    """The standard drill script: every :data:`CRASH_POINTS` boundary is
    reachable from it under both fsync policies.  The explicit ``flush``
    fires ``wal.fsync.post`` under group-commit (under per-record fsync
    that point fires at the first insert instead); the explicit
    ``snapshot`` precedes the re-index so the ``snapshot.*`` /
    ``wal.truncate.*`` points fire at a scripted boundary."""
    rng = np.random.default_rng(seed)
    row = lambda b: rng.standard_normal((b, d)).astype(np.float32)  # noqa: E731
    return [
        DrillStep("insert", row(3)),
        DrillStep("flush"),
        DrillStep("delete", np.asarray([0, 1], np.int64)),
        DrillStep("snapshot"),
        DrillStep("insert", row(2)),
        DrillStep("reindex"),
        DrillStep("insert", row(2)),
    ]


@dataclasses.dataclass(frozen=True)
class DrillReport:
    """Outcome of one kill → recover → verify drill."""

    crash_point: str
    fired: bool  # the armed boundary was actually reached
    acked: int  # mutation records acknowledged before the kill
    applied: int  # records reflected in the recovered state
    lost_acked: int  # max(0, acked - applied): MUST be 0
    bit_identical: bool  # fingerprints match the crash-free reference
    fingerprint_diff: tuple[str, ...]
    retraces_after_warmup: int  # executable growth while serving: MUST be 0
    answers_match: bool  # recovered answers == reference answers
    quality_bounds_match: bool  # Theorem-2 floors agree with the reference
    dropped_bytes: int  # torn WAL tail truncated during recovery
    snapshots_skipped: int


def _apply_drill_step(server, manager, dur, step: DrillStep) -> None:
    if step.kind == "insert":
        manager.insert(step.payload)
    elif step.kind == "delete":
        manager.delete(step.payload)
    elif step.kind == "reindex":
        manager.reindex()
    elif step.kind == "snapshot":
        dur.snapshot()
    elif step.kind == "flush":
        dur.flush()
    else:
        raise ValueError(f"unknown drill step kind {step.kind!r}")


def _drill_answers(server, queries, k: int):
    """Serve ``queries`` one at a time (the warmed batch-1 bucket) and
    return their ``(ids, dists)`` in order."""
    out = []
    for i, q in enumerate(queries):
        req = AnnRequest(i, np.asarray(q, np.float32), k=k)  # jaxlint: sync-ok — host payload
        server.submit(req)
        while server.queue:
            server.step()
        if getattr(server, "inflight", 0):
            server.flush()
        out.append((req.ids, req.dists))
    return out


def recovery_drill(
    root,
    build: Callable,
    steps: Sequence[DrillStep],
    crash_point: str,
    *,
    queries: np.ndarray,
    k: int = 10,
    recover_kwargs: dict | None = None,
) -> DrillReport:
    """Kill a durable serving stack at ``crash_point``, recover it, and
    verify the durability contract against a crash-free reference.

    ``build(dir, injector)`` constructs a fresh serving stack rooted at
    ``dir`` — returning ``(server, manager, durability)`` with the
    injector wired into the :class:`~repro.serve.durability.Durability`
    (``crash=injector``) and ``start_worker=False`` (drills drive the
    group-commit flush synchronously via :class:`DrillStep` so the kill
    schedule is deterministic).

    Protocol: build → clean baseline snapshot → arm → run ``steps``
    counting acknowledged records until :class:`CrashPoint` (or script
    end) → abandon (no final flush: the OS page cache is all recovery
    gets) → :func:`repro.serve.durability.recover` → rebuild a reference
    stack in a sibling directory and replay the acknowledged prefix
    crash-free → compare byte-for-byte:

    * zero acknowledged records lost (``applied >= acked``; the one-past
      case is a record that was framed but whose ack never returned);
    * state fingerprints bit-identical to the reference;
    * recovered answers identical, with zero retraces while serving
      (the snapshot's warm surface covers the traffic);
    * Theorem-2 quality floors agree with the reference ladder.
    """
    root = Path(root)
    crash_dir, ref_dir = root / "crash", root / "ref"
    injector = CrashInjector()
    server, manager, dur = build(crash_dir, injector)
    dur.snapshot()  # clean baseline — every drill starts recoverable
    injector.arm(crash_point)
    acked = 0
    try:
        for step in steps:
            _apply_drill_step(server, manager, dur, step)
            acked += step.records
    except CrashPoint:
        pass
    dur.abandon()  # process death: no orderly flush

    from repro.serve.durability import (  # lazy: chaos must import light
        fingerprint_diff,
        recover,
        state_fingerprint,
    )

    rec = recover(crash_dir, start_worker=False, **(recover_kwargs or {}))
    applied = rec.report.applied_records

    ref_server, ref_manager, ref_dur = build(ref_dir, CrashInjector())
    cum = 0
    for step in steps:
        if cum + step.records > applied:
            break
        _apply_drill_step(ref_server, ref_manager, ref_dur, step)
        cum += step.records

    diff = fingerprint_diff(
        state_fingerprint(rec.server, rec.manager),
        state_fingerprint(ref_server, ref_manager),
    )
    exe0 = rec.server.executables
    got = _drill_answers(rec.server, queries, k)
    retraces = rec.server.executables - exe0
    want = _drill_answers(ref_server, queries, k)
    answers_match = all(
        np.array_equal(g[0], w[0]) and np.array_equal(g[1], w[1])
        for g, w in zip(got, want)
    )
    bounds_match = True
    if rec.server.ladder is not None and ref_server.ladder is not None:
        bounds_match = all(
            rec.server.ladder.quality_bound(lv, k)
            == ref_server.ladder.quality_bound(lv, k)
            for lv in range(rec.server.ladder.max_level + 1)
        )
    rec.durability.close()
    ref_dur.close()
    return DrillReport(
        crash_point=crash_point,
        fired=injector.fired,
        acked=acked,
        applied=applied,
        lost_acked=max(0, acked - applied),
        bit_identical=not diff,
        fingerprint_diff=diff,
        retraces_after_warmup=retraces,
        answers_match=answers_match,
        quality_bounds_match=bounds_match,
        dropped_bytes=rec.report.dropped_bytes,
        snapshots_skipped=rec.report.snapshots_skipped,
    )


def kill_pool_engine(pool, k: int, reason: str = "injected shard death") -> None:
    """Make ``pool``'s per-``k`` engine raise :class:`ChaosError` on every
    query — the shard-death injector for
    :class:`~repro.distributed.engine.ShardedEnginePool.query_resilient`,
    which must rebind the dead k-class to a healthy engine."""
    engine = pool.engine_for(k)

    def _dead_query(q, k=k, **kw):
        raise ChaosError(f"{reason} (k={k})")

    engine.query = _dead_query
