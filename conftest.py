"""Repo-root pytest guard: make `python -m pytest` work in a bare,
network-less environment.

* Puts ``src/`` on ``sys.path`` so ``import repro`` works even when the
  caller forgot ``PYTHONPATH=src``.
* Puts ``tests/`` on ``sys.path`` so the vendored
  ``tests/_hypothesis_fallback.py`` shim is importable from test modules
  regardless of pytest's rootdir/import mode.
* Registers the ``slow`` marker: nightly-sized cases (e.g. the
  streaming-scale recall guarantee) that the full local run includes but
  CI deselects with ``-m "not slow"``.
"""

from __future__ import annotations

import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent
for _p in (_ROOT / "src", _ROOT / "tests"):
    p = str(_p)
    if p not in sys.path:
        sys.path.insert(0, p)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: nightly-sized case — run locally/nightly, deselected in CI "
        'via -m "not slow"',
    )
