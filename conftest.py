"""Repo-root pytest guard: make `python -m pytest` work in a bare,
network-less environment.

* Puts ``src/`` on ``sys.path`` so ``import repro`` works even when the
  caller forgot ``PYTHONPATH=src``.
* Puts ``tests/`` on ``sys.path`` so the vendored
  ``tests/_hypothesis_fallback.py`` shim is importable from test modules
  regardless of pytest's rootdir/import mode.
"""

from __future__ import annotations

import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent
for _p in (_ROOT / "src", _ROOT / "tests"):
    p = str(_p)
    if p not in sys.path:
        sys.path.insert(0, p)
