"""End-to-end serving driver: retrieval-augmented generation.

A small LM embeds a synthetic document corpus (mean-pooled hidden states),
a SuCoEngine serves the embedding index, and batched requests flow through
the pipelined continuous micro-batching AsyncAnnServer (retrieve) ->
prompt-augment -> prefill -> continuous-batching decode.  Both stages
share the same admission-queue serving design; the retrieval side is the
paper's technique deployed as the retrieval layer of an LLM serving stack.

    PYTHONPATH=src python examples/rag_serving.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.core import EnginePolicy, SuCoConfig, SuCoEngine
from repro.launch.serve import Request, Server
from repro.models import Model, backbone
from repro.serve.ann import AnnRequest, AsyncAnnServer, latency_summary


def embed(model: Model, params, tokens: jnp.ndarray) -> jnp.ndarray:
    """Mean-pooled final hidden states as document/query embeddings."""
    hidden = backbone.forward_hidden(model.cfg, params, tokens, remat=False)
    return jnp.mean(hidden.astype(jnp.float32), axis=1)


def main() -> None:
    rng = np.random.default_rng(0)
    cfg = reduced_config("granite-3-2b")
    model = Model(cfg)
    params = model.init(jax.random.key(0))

    # --- corpus: 4096 synthetic documents of 24 tokens
    n_docs, doc_len = 4096, 24
    docs = rng.integers(0, cfg.vocab_size, (n_docs, doc_len)).astype(np.int32)
    t0 = time.perf_counter()
    emb = np.asarray(
        jax.lax.map(lambda t: embed(model, params, t),
                    jnp.asarray(docs).reshape(32, n_docs // 32, doc_len))
    ).reshape(n_docs, cfg.d_model)
    print(f"embedded {n_docs} docs in {time.perf_counter()-t0:.1f}s -> {emb.shape}")

    # --- SuCoEngine over document embeddings: the persistent retrieval stage
    engine = SuCoEngine.build(
        jnp.asarray(emb),
        SuCoConfig(n_subspaces=8, sqrt_k=16, kmeans_iters=6),
        policy=EnginePolicy(alpha=0.1, beta=0.05),
    )
    print(f"SuCo index: {engine.index.memory_bytes()/1e3:.0f} KB for "
          f"{emb.nbytes/1e3:.0f} KB of embeddings (mode={engine.mode})")

    # --- requests: queries are noisy copies of random docs
    n_req = 6
    target = rng.integers(0, n_docs, n_req)
    queries = docs[target].copy()
    queries[:, -2:] = rng.integers(0, cfg.vocab_size, (n_req, 2))
    q_emb = embed(model, params, jnp.asarray(queries))

    # --- retrieval via the pipelined continuous micro-batching ANN server:
    # with several micro-batches queued, dispatch of batch t+1 overlaps the
    # device executing batch t.  Prefer the synchronous AnnServer when the
    # queue rarely holds more than one batch (interactive single requests)
    # — there pipelining only defers materialisation without overlap.
    engine.warmup(batch_sizes=(1, 3), ks=(3,))
    ann = AsyncAnnServer(engine, max_batch=3, depth=2)
    ann.submit_many(
        [AnnRequest(i, np.asarray(q_emb[i]), k=3) for i in range(n_req)]
    )
    done = ann.run_until_drained()
    lat = latency_summary(done)
    hit = np.mean([int(target[r.rid]) in set(map(int, r.ids)) for r in done])
    print(f"retrieval hit-rate (true doc in top-3): {hit:.2f} "
          f"({lat['qps']:.0f} qps, p99 {lat['p99_ms']:.1f} ms, "
          f"{len(ann.steps)} micro-batches, "
          f"executables {engine.compile_count})")

    # --- augment prompts with the top doc and serve
    by_rid = {r.rid: r for r in done}
    top_docs = docs[np.asarray([by_rid[i].ids[0] for i in range(n_req)])]
    prompts = np.concatenate([top_docs, queries], axis=1)  # (n_req, 48)
    reqs = [Request(i, prompts[i]) for i in range(n_req)]
    server = Server(model, params, n_slots=3, max_seq=prompts.shape[1] + 12)
    t0 = time.perf_counter()
    done = server.run(reqs, gen_len=8)
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.generated) for r in done)
    print(f"served {len(done)} RAG requests, {n_tok} tokens in {dt:.1f}s "
          f"({n_tok/dt:.1f} tok/s)")
    for r in done[:3]:
        print(f"  request {r.rid}: generated {r.generated}")


if __name__ == "__main__":
    main()
