"""End-to-end serving driver: retrieval-augmented generation.

A small LM embeds a synthetic document corpus (mean-pooled hidden states),
SuCo indexes the embeddings, and batched requests flow through
retrieve -> prompt-augment -> prefill -> continuous-batching decode.

This is the paper's technique deployed as the retrieval layer of an LLM
serving stack — the framework's primary end-to-end driver.

    PYTHONPATH=src python examples/rag_serving.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.core import SuCoConfig, build_index, suco_query
from repro.launch.serve import Request, Server
from repro.models import Model, backbone


def embed(model: Model, params, tokens: jnp.ndarray) -> jnp.ndarray:
    """Mean-pooled final hidden states as document/query embeddings."""
    hidden = backbone.forward_hidden(model.cfg, params, tokens, remat=False)
    return jnp.mean(hidden.astype(jnp.float32), axis=1)


def main() -> None:
    rng = np.random.default_rng(0)
    cfg = reduced_config("granite-3-2b")
    model = Model(cfg)
    params = model.init(jax.random.key(0))

    # --- corpus: 4096 synthetic documents of 24 tokens
    n_docs, doc_len = 4096, 24
    docs = rng.integers(0, cfg.vocab_size, (n_docs, doc_len)).astype(np.int32)
    t0 = time.perf_counter()
    emb = np.asarray(
        jax.lax.map(lambda t: embed(model, params, t),
                    jnp.asarray(docs).reshape(32, n_docs // 32, doc_len))
    ).reshape(n_docs, cfg.d_model)
    print(f"embedded {n_docs} docs in {time.perf_counter()-t0:.1f}s -> {emb.shape}")

    # --- SuCo index over document embeddings
    index = build_index(jnp.asarray(emb), SuCoConfig(n_subspaces=8, sqrt_k=16,
                                                     kmeans_iters=6))
    print(f"SuCo index: {index.memory_bytes()/1e3:.0f} KB for "
          f"{emb.nbytes/1e3:.0f} KB of embeddings")

    # --- requests: queries are noisy copies of random docs
    n_req = 6
    target = rng.integers(0, n_docs, n_req)
    queries = docs[target].copy()
    queries[:, -2:] = rng.integers(0, cfg.vocab_size, (n_req, 2))
    q_emb = embed(model, params, jnp.asarray(queries))

    res = suco_query(jnp.asarray(emb), index, q_emb, k=3, alpha=0.1, beta=0.05)
    hit = np.mean([int(t) in set(map(int, ids)) for t, ids in zip(target, res.ids)])
    print(f"retrieval hit-rate (true doc in top-3): {hit:.2f}")

    # --- augment prompts with the top doc and serve
    top_docs = docs[np.asarray(res.ids[:, 0])]
    prompts = np.concatenate([top_docs, queries], axis=1)  # (n_req, 48)
    reqs = [Request(i, prompts[i]) for i in range(n_req)]
    server = Server(model, params, n_slots=3, max_seq=prompts.shape[1] + 12)
    t0 = time.perf_counter()
    done = server.run(reqs, gen_len=8)
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.generated) for r in done)
    print(f"served {len(done)} RAG requests, {n_tok} tokens in {dt:.1f}s "
          f"({n_tok/dt:.1f} tok/s)")
    for r in done[:3]:
        print(f"  request {r.rid}: generated {r.generated}")


if __name__ == "__main__":
    main()
