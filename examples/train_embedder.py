"""End-to-end training driver: train a small LM for a few hundred steps with
checkpoint/resume (CPU-scaled; the same driver runs any --arch on a mesh).

    PYTHONPATH=src python examples/train_embedder.py
"""

import argparse

from repro.launch.train import train_once


def main() -> None:
    args = argparse.Namespace(
        arch="granite-3-2b", reduced=True, steps=200, global_batch=8,
        seq_len=64, d_model=0, micro_steps=1, lr=2e-3, seed=0, no_remat=False,
        ckpt_dir="/tmp/repro_train_embedder", ckpt_every=50, log_every=20,
        mesh="none",
    )
    train_once(args)


if __name__ == "__main__":
    main()
