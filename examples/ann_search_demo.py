"""ANN search demo: theory-driven parameter choice, SC-Linear vs the
SuCoEngine serving subsystem vs competitors, L1 and L2 metrics, and the
persisted-index artifact round trip.

    PYTHONPATH=src python examples/ann_search_demo.py
"""

import tempfile
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines import IVFFlat, HNSWLite
from repro.core import (
    EnginePolicy,
    SuCoConfig,
    SuCoEngine,
    contiguous_spec,
    sc_linear_query,
)
from repro.core.theory import subspace_statistics, suggest_parameters
from repro.data import exact_knn, make_dataset, recall


def main() -> None:
    ds = make_dataset("correlated", n=30_000, d=64, m=40, k=10)
    x, q = jnp.asarray(ds.x), jnp.asarray(ds.queries)
    n, d = ds.x.shape

    print("== theory-driven parameters (Theorems 1-2) ==")
    m_stat, s_stat = subspace_statistics(ds.x, ds.queries[0], 8)
    sugg = suggest_parameters(n=n, d=d, k=10, m=m_stat, sigma=s_stat)
    print(f"subspace stats m={m_stat:.2f} sigma={s_stat:.2f} -> {sugg}")
    alpha = max(sugg["alpha"], 0.05)
    beta = max(sugg["beta"], 0.01)

    print("\n== SC-Linear (Algorithm 1, no index) ==")
    spec = contiguous_spec(d, sugg["n_subspaces"])
    t0 = time.perf_counter()
    res = sc_linear_query(x, q, spec=spec, k=10, alpha=alpha, beta=beta)
    jax.block_until_ready(res.ids)
    print(f"recall={recall(np.asarray(res.ids), ds.gt_ids):.4f} "
          f"({(time.perf_counter()-t0)*1e3:.0f} ms incl. compile)")

    print("\n== SuCoEngine (Algorithms 2-4 as a serving subsystem) ==")
    config = SuCoConfig(n_subspaces=sugg["n_subspaces"], sqrt_k=32, kmeans_iters=8)
    engine = SuCoEngine.build(x, config, policy=EnginePolicy(alpha=alpha, beta=beta))
    engine.warmup(batch_sizes=(q.shape[0],), ks=(10,))  # pre-compile the bucket
    t0 = time.perf_counter()
    res = engine.query(q, k=10)
    jax.block_until_ready(res.ids)
    dt = time.perf_counter() - t0
    print(f"recall={recall(np.asarray(res.ids), ds.gt_ids):.4f} "
          f"query {dt*1e3:.1f} ms (warmed, mode={engine.mode}), "
          f"index {engine.index.memory_bytes()/1e6:.1f} MB, "
          f"executables {engine.compile_count}")

    print("\n== index persistence (save/load artifact) ==")
    with tempfile.TemporaryDirectory() as td:
        path = Path(td) / "suco_index.npz"
        engine.save(path, config)
        served = SuCoEngine.from_artifact(
            path, x, policy=EnginePolicy(alpha=alpha, beta=beta)
        )
        res2 = served.query(q, k=10)
        same = bool(np.array_equal(np.asarray(res.ids), np.asarray(res2.ids)))
        print(f"artifact {path.stat().st_size/1e6:.1f} MB, "
              f"loaded engine bit-identical: {same}")

    print("\n== L1 metric (Table 5) ==")
    gt_l1, _ = exact_knn(ds.x, ds.queries, 10, metric="l1")
    l1_engine = SuCoEngine(
        x, engine.index, EnginePolicy(alpha=alpha, beta=beta, metric="l1")
    )
    res = l1_engine.query(q, k=10)
    print(f"recall(L1)={recall(np.asarray(res.ids), gt_l1):.4f}")

    print("\n== competitors ==")
    for name, idx, kw in (
        ("IVF-Flat", IVFFlat(n_cells=128, iters=5).build(ds.x), dict(nprobe=8)),
        ("HNSW-lite", HNSWLite(m=12, ef_construction=48).build(ds.x), dict(ef_search=64)),
    ):
        t0 = time.perf_counter()
        ids = idx.query(ds.queries, 10, **kw)
        dt = time.perf_counter() - t0
        print(f"{name:10s} recall={recall(ids, ds.gt_ids):.4f} "
              f"query {dt*1e3:.1f} ms, mem {idx.memory_bytes()/1e6:.1f} MB")


if __name__ == "__main__":
    main()
