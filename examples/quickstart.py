"""Quickstart: build a SuCo index and answer k-ANN queries.

    PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SuCoConfig, build_index, suco_query
from repro.data import make_dataset, recall, mean_relative_error


def main() -> None:
    print("== SuCo quickstart ==")
    ds = make_dataset("gaussian_mixture", n=50_000, d=96, m=50, k=10)
    x, q = jnp.asarray(ds.x), jnp.asarray(ds.queries)

    cfg = SuCoConfig(n_subspaces=8, sqrt_k=32, kmeans_iters=8)
    t0 = time.perf_counter()
    index = build_index(x, cfg)
    jax.block_until_ready(index.cell_ids)
    print(f"index built in {time.perf_counter()-t0:.2f}s, "
          f"footprint {index.memory_bytes()/1e6:.1f} MB "
          f"(dataset {ds.x.nbytes/1e6:.1f} MB)")

    res = suco_query(x, index, q, k=10, alpha=0.05, beta=0.01)
    jax.block_until_ready(res.ids)
    t0 = time.perf_counter()
    res = suco_query(x, index, q, k=10, alpha=0.05, beta=0.01)
    jax.block_until_ready(res.ids)
    dt = time.perf_counter() - t0
    print(f"answered {q.shape[0]} queries in {dt*1e3:.1f} ms "
          f"({q.shape[0]/dt:.0f} QPS)")
    print(f"recall@10 = {recall(np.asarray(res.ids), ds.gt_ids):.4f}, "
          f"MRE = {mean_relative_error(np.asarray(res.dists), ds.gt_dists):.5f}")


if __name__ == "__main__":
    main()
