"""Beyond-paper demo: Subspace-Collision sparse attention for long-context
decode — select top keys by SC-score, attend exactly over the selection.

    PYTHONPATH=src python examples/long_context_sc_attention.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sc_attention import attention_mass_recall, sc_sparse_attention


def main() -> None:
    rng = np.random.default_rng(0)
    h, s, hd = 8, 65_536, 64
    print(f"== SC sparse attention: {h} heads x {s} keys x {hd} dims ==")
    # keys with locality structure (recent tokens matter more)
    base = rng.normal(size=(h, s, hd)).astype(np.float32)
    drift = np.linspace(0, 2, s)[None, :, None]
    keys = jnp.asarray(base + drift * rng.normal(size=(h, 1, hd)))
    values = jnp.asarray(rng.normal(size=(h, s, hd)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(h, hd)).astype(np.float32) + np.asarray(keys[:, -1]))

    for n_keep in (512, 2048, 8192):
        t0 = time.perf_counter()
        out, ids = sc_sparse_attention(
            q, keys, values, n_subspaces=4, alpha=0.05, n_keep=n_keep
        )
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        mass = attention_mass_recall(q, keys, ids)
        # exact attention for error reference
        logits = jnp.einsum("hd,hsd->hs", q, keys) / np.sqrt(hd)
        w = jax.nn.softmax(logits, axis=-1)
        exact = jnp.einsum("hs,hsd->hd", w, values)
        err = float(jnp.abs(out - exact).max())
        print(f"n_keep={n_keep:5d} ({n_keep/s:6.2%} of keys): "
              f"attention-mass recall {float(mass.mean()):.4f}, "
              f"max|err| {err:.4f}, {dt*1e3:.0f} ms")


if __name__ == "__main__":
    main()
